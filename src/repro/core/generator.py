"""Generation stage (paper §3.3.4): a JAX serving engine behind ``BaseLLM``.

``ModelLLM`` is the lock-step baseline: batched prefill fills the KV cache,
then a jit'd greedy decode loop emits tokens.  TTFT / TPOT are recorded
**per request** (the paper reads the same two metrics off vLLM's endpoint) —
jit-padding rows added for shape stability are never counted.  On
transformer families the decode runs with *per-row* positions, so a row's
output depends only on its own unpadded prompt; that makes lock-step output
identical to the token-level continuous-batching engine
(``repro.serving.genengine``) for the same admission order.  Any architecture
in the zoo plugs in via its ModelConfig — the RAG pipeline is model-agnostic,
which is the paper's point.

``ExtractiveLLM`` is the deterministic quality oracle: it answers from the
retrieved context with template matching.  Random-weight models cannot produce
graded answers, so accuracy benchmarks (paper Fig. 8/9) use this backend while
performance benchmarks use ``ModelLLM`` (DESIGN.md §2).
"""
from __future__ import annotations

import re
import threading
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interfaces import BaseLLM, Chunk
from repro.core.registry import register
from repro.core.tokenizer import HashTokenizer
from repro.models import api
from repro.models.config import ModelConfig

PROMPT_TEMPLATE = ("answer the question using the context\n"
                   "context: {context}\nquestion: {question}\nanswer:")

# families whose serving path runs through repro.models.transformer and
# supports per-row decode positions (vector ``cache["pos"]``)
PER_ROW_POS_FAMILIES = ("dense", "moe", "vlm")


def build_prompt(question: str, contexts: Sequence[Chunk]) -> str:
    ctx = " ".join(c.text for c in contexts)
    return PROMPT_TEMPLATE.format(context=ctx, question=question)


def render_tokens(ids: Sequence[int]) -> str:
    """The shared id->text rendering for random-weight generation output
    (the hash tokenizer has no decoder).  Lock-step, engine and benchmark
    outputs must render identically for equivalence checks to mean
    anything, so there is exactly one implementation."""
    return " ".join(f"tok{t}" for t in ids)


@dataclass
class GenStats:
    """Per-request generation metrics, safe under concurrent recording.

    Replicated generate-stage workers (``ElasticExecutor`` warm pools) share
    one ``GenStats``: every mutation happens under the internal lock, so no
    sample is lost when two engines retire requests simultaneously.  Only
    *real* requests are recorded — jit-padding rows never reach ``record``.
    """

    ttft_s: List[float] = field(default_factory=list)   # guarded-by: _lock
    tpot_s: List[float] = field(default_factory=list)   # guarded-by: _lock
    tokens_out: int = 0                                 # guarded-by: _lock
    n_requests: int = 0                                 # guarded-by: _lock
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def record(self, ttft_s: float, tpot_s: float, tokens: int) -> None:
        """Record one completed request (thread-safe)."""
        with self._lock:
            self.ttft_s.append(float(ttft_s))
            self.tpot_s.append(float(tpot_s))
            self.tokens_out += int(tokens)
            self.n_requests += 1

    def merge(self, other: "GenStats") -> None:
        """Fold another stats object in (per-engine stats at summary time)."""
        with other._lock:
            ttft, tpot = list(other.ttft_s), list(other.tpot_s)
            tokens, n = other.tokens_out, other.n_requests
        with self._lock:
            self.ttft_s.extend(ttft)
            self.tpot_s.extend(tpot)
            self.tokens_out += tokens
            self.n_requests += n

    def summary(self) -> Dict[str, float]:
        with self._lock:
            ttft, tpot = list(self.ttft_s), list(self.tpot_s)
            tokens, n = self.tokens_out, self.n_requests
        return {
            "ttft_mean_s": float(np.mean(ttft)) if ttft else 0.0,
            "tpot_mean_s": float(np.mean(tpot)) if tpot else 0.0,
            "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_p95_s": float(np.percentile(ttft, 95)) if ttft else 0.0,
            "tpot_p95_s": float(np.percentile(tpot, 95)) if tpot else 0.0,
            "tokens_out": float(tokens),
            "n_requests": float(n),
        }


class ModelLLM(BaseLLM):
    """Batched prefill + KV-cache greedy decode over any zoo architecture."""

    def __init__(self, cfg: ModelConfig, max_prompt: int = 256,
                 max_new: int = 16, batch_size: int = 8, seed: int = 0,
                 stats: Optional[GenStats] = None):
        self.cfg = cfg
        self.model = api.get_model(cfg)
        self.max_prompt = max_prompt
        self.max_new = max_new
        self._max_new_cap = max_new
        self.batch_size = batch_size
        self.tok = HashTokenizer(cfg.vocab_size)
        self.params = self.model.init(jax.random.PRNGKey(seed), cfg)
        self.stats = stats if stats is not None else GenStats()
        # transformer families decode with per-row positions, so right-padded
        # prompt rows generate exactly as they would unpadded
        self._per_row_pos = cfg.family in PER_ROW_POS_FAMILIES
        self._prefill = jax.jit(partial(self.model.prefill, cfg=cfg))
        self._decode = jax.jit(partial(self.model.decode_step, cfg=cfg))

    def clone(self) -> "ModelLLM":
        """A replica view for warm-pool workers: shares params, jit caches
        and the (thread-safe) stats; per-call state is already local."""
        twin = object.__new__(ModelLLM)
        twin.__dict__.update(self.__dict__)
        return twin

    def set_max_new(self, n: int) -> int:
        """Autoscale knob: clamp decode length to [1, configured max]."""
        self.max_new = max(1, min(int(n), self._max_new_cap))
        return self.max_new

    def _make_batch(self, tokens: np.ndarray) -> Dict:
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            # backbone-only: pretend patch embeddings for the token ids
            B, S = tokens.shape
            batch = {"embeds": jnp.zeros((B, S, self.cfg.d_model),
                                         jnp.dtype(self.cfg.dtype))}
        if self.cfg.family == "audio":
            B = tokens.shape[0]
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return batch

    def generate(self, prompts: Sequence[str],
                 contexts: Sequence[Sequence[Chunk]]) -> List[str]:
        out: List[str] = []
        bs = self.batch_size
        for lo in range(0, len(prompts), bs):
            chunk_p = prompts[lo:lo + bs]
            chunk_c = contexts[lo:lo + bs]
            texts = [build_prompt(p, c) for p, c in zip(chunk_p, chunk_c)]
            tokens = self.tok.encode_batch(texts, self.max_prompt)
            if len(texts) < bs:   # pad batch dim for jit shape stability
                tokens = np.pad(tokens, ((0, bs - len(texts)), (0, 0)))
            out.extend(self._generate_batch(tokens, n_real=len(texts)))
        return out

    def _generate_batch(self, tokens: np.ndarray, n_real: int) -> List[str]:
        """Generate for one padded batch; only the first ``n_real`` rows are
        real requests — they alone are timed, counted and returned."""
        B = tokens.shape[0]
        max_new = self.max_new
        max_len = self.max_prompt + max_new
        cache = self.model.init_cache(self.cfg, B, max_len)
        t0 = time.perf_counter()
        batch = self._make_batch(tokens)
        if self._per_row_pos:
            # per-row true prompt lengths (pad_id == 0 never appears in real
            # content); an all-pad row still reads one position
            lengths = np.maximum((tokens != 0).sum(axis=1), 1).astype(np.int32)
            logits, cache = self._prefill(self.params, batch=batch,
                                          cache=cache,
                                          lengths=jnp.asarray(lengths))
        else:
            logits, cache = self._prefill(self.params, batch=batch,
                                          cache=cache)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        jax.block_until_ready(first)
        ttft = time.perf_counter() - t0
        toks = [first]
        cur = jnp.asarray(first[:, None].astype(np.int32))
        t1 = time.perf_counter()
        for _ in range(max_new - 1):
            step = {"tokens": cur}
            if self.cfg.family == "vlm":
                step = {"embeds": jnp.zeros(
                    (B, 1, self.cfg.d_model), jnp.dtype(self.cfg.dtype))}
            logits, cache = self._decode(self.params, batch=step, cache=cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cur = nxt[:, None]
            toks.append(np.asarray(nxt))
        jax.block_until_ready(cur)
        n_steps = max(max_new - 1, 1)
        tpot = (time.perf_counter() - t1) / n_steps
        # lock-step semantics: every real request in the batch saw its first
        # token after the shared prefill and decoded at the shared cadence
        for _ in range(n_real):
            self.stats.record(ttft, tpot, max_new)
        ids = np.stack(toks, axis=1)[:n_real]          # [n_real, max_new]
        return [render_tokens(row) for row in ids]


_FACT = re.compile(r"the (\w+) of ([\w\-]+) is ([\w\-]+)")
_Q = re.compile(r"what is the (\w+) of ([\w\-]+)")


@register("llm", "extractive")
class ExtractiveLLM(BaseLLM):
    """Deterministic reader: extracts `the <attr> of <subj> is <val>` facts
    from the retrieved context.  Highest-version chunk wins (freshness)."""

    def generate(self, prompts: Sequence[str],
                 contexts: Sequence[Sequence[Chunk]]) -> List[str]:
        out = []
        for q, ctx in zip(prompts, contexts):
            m = _Q.search(q.lower())
            answer = ""
            if m:
                attr, subj = m.group(1), m.group(2)
                best_ver = -1
                for c in ctx:
                    for fm in _FACT.finditer(c.text.lower()):
                        if fm.group(1) == attr and fm.group(2) == subj \
                                and c.version >= best_ver:
                            best_ver = c.version
                            answer = fm.group(3)
            out.append(answer)
        return out


@register("llm", "model")
def _model_llm(arch: str = "", smoke: bool = True, max_prompt: int = 256,
               max_new: int = 16, batch_size: int = 8, seed: int = 0,
               cfg: Optional[ModelConfig] = None) -> ModelLLM:
    """Spec-friendly ModelLLM factory: resolves the architecture id to its
    (smoke or published) ModelConfig unless one is passed directly."""
    if cfg is None:
        assert arch, "llm 'model' needs an 'arch' option or a cfg"
        from repro import configs as arch_configs
        cfg = (arch_configs.get_smoke(arch) if smoke
               else arch_configs.get_config(arch))
    return ModelLLM(cfg, max_prompt=max_prompt, max_new=max_new,
                    batch_size=batch_size, seed=seed)


def make_llm(kind: str = "extractive", cfg: Optional[ModelConfig] = None,
             **kw) -> BaseLLM:
    from repro.core import registry
    if cfg is not None:
        kw["cfg"] = cfg
    return registry.create("llm", kind, **kw)
