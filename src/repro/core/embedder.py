"""Embedding stage (paper §3.3.1).

Two JAX-native embedders behind ``BaseEmbedder``:

``TransformerEmbedder`` — bidirectional encoder (our transformer layers run
    non-causally) with masked mean pooling + L2 norm.  This is the
    performance-realistic path: its FLOP/byte profile matches a
    SentenceTransformer-class model, and it TP/DP-shards like any model in
    the zoo.  Weights are random (no pretrained weights offline), so it is
    used for *performance* characterization.

``HashEmbedder`` — deterministic bag-of-tokens + fixed random projection
    (SimHash-style).  Documents sharing vocabulary land close in cosine
    space, so retrieval *quality* metrics (context recall etc.) are
    meaningful without any training.  Used for accuracy benchmarks.

Embedding dimension is a config knob in both (paper Fig. 11 sweeps it).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interfaces import BaseEmbedder
from repro.core.registry import register
from repro.core.tokenizer import HashTokenizer
from repro.models import layers as L
from repro.models.config import ModelConfig


def encoder_config(d_model: int = 256, n_layers: int = 4, n_heads: int = 4,
                   dim: int = 384, vocab: int = 32768) -> ModelConfig:
    return ModelConfig(
        name=f"embedder-{dim}", family="dense", n_layers=n_layers,
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_heads,
        d_ff=4 * d_model, vocab_size=vocab, activation="gelu",
        rope_type="rope", rope_theta=10000.0, remat="none")


@register("embedder", "hash")
class HashEmbedder(BaseEmbedder):
    """Deterministic token-bag embedding: E[token] rows from a fixed random
    Gaussian, mean-pooled, L2-normalized.  Zero model FLOPs; pure lookup."""

    def __init__(self, dim: int = 384, vocab_size: int = 32768, seed: int = 0):
        self.dim = dim
        self.tok = HashTokenizer(vocab_size)
        key = jax.random.PRNGKey(seed)
        # fixed projection table, host-side
        self.table = np.asarray(
            jax.random.normal(key, (vocab_size, dim), jnp.float32)) / math.sqrt(dim)

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), dtype=np.float32)
        for i, t in enumerate(texts):
            ids = self.tok.encode(t)
            if ids:
                v = self.table[np.asarray(ids)].mean(0)
                out[i] = v / (np.linalg.norm(v) + 1e-9)
        return out


@register("embedder", "transformer")
class TransformerEmbedder(BaseEmbedder):
    """Bidirectional transformer encoder + masked mean pool + projection."""

    def __init__(self, dim: int = 384, d_model: int = 256, n_layers: int = 4,
                 max_len: int = 128, seed: int = 0, batch_size: int = 64):
        self.dim = dim
        self.max_len = max_len
        self.batch_size = batch_size
        self.cfg = encoder_config(d_model=d_model, n_layers=n_layers, dim=dim)
        self.tok = HashTokenizer(self.cfg.vocab_size)
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        from repro.models import transformer
        self.params = transformer.init(k1, self.cfg)
        self.proj = L.dense_init(k2, (d_model, dim), jnp.float32)
        self._encode = jax.jit(partial(_encode_fn, cfg=self.cfg))

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), dtype=np.float32)
        for lo in range(0, len(texts), self.batch_size):
            batch = texts[lo:lo + self.batch_size]
            tokens = self.tok.encode_batch(batch, self.max_len)
            # pad the batch dim so jit sees a fixed shape
            n = len(batch)
            if n < self.batch_size:
                tokens = np.pad(tokens, ((0, self.batch_size - n), (0, 0)))
            vecs = self._encode(self.params, self.proj, jnp.asarray(tokens))
            out[lo:lo + n] = np.asarray(vecs)[:n]
        return out


def _encode_fn(params, proj, tokens, *, cfg: ModelConfig):
    """Non-causal encoder forward -> unit vectors [B, dim]."""
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        h = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        h = L.multihead_attention(lp["attn"], h, positions, cfg, causal=False)
        x = x + h
        h = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, cfg.activation)
        return x, ()

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    mask = (tokens > 0).astype(jnp.float32)[..., None]
    pooled = (x.astype(jnp.float32) * mask).sum(1) / jnp.maximum(mask.sum(1), 1.0)
    v = pooled @ proj
    return v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-9)


def make_embedder(kind: str = "hash", **kw) -> BaseEmbedder:
    from repro.core import registry
    return registry.create("embedder", kind, **kw)
