"""Serve the RAG pipeline with different generation backbones (--arch),
exactly the paper's model-swap experiment (§5.2): the pipeline is untouched,
only the BaseLLM backend changes.

    PYTHONPATH=src python examples/serve_multiarch.py
    PYTHONPATH=src python examples/serve_multiarch.py --arch zamba2_2_7b
"""
import argparse

from repro import configs
from repro.core.generator import ModelLLM
from repro.core.pipeline import PipelineConfig, RAGPipeline
from repro.workload.corpus import CorpusConfig, SyntheticCorpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ["llama3_8b", "qwen3_moe_30b_a3b",
                                           "xlstm_1_3b"]
    corpus = SyntheticCorpus(CorpusConfig(n_docs=32))
    questions = [f"what is the {corpus.facts[d][0].attribute} of "
                 f"{corpus.facts[d][0].subject}?" for d in range(8)]
    for arch in archs:
        # reduced same-family config on CPU; full config on a real mesh
        llm = ModelLLM(configs.get_smoke(arch), max_prompt=96, max_new=4,
                       batch_size=4)
        pipe = RAGPipeline(PipelineConfig(retrieve_k=4, rerank_k=2), llm=llm)
        pipe.index_documents(corpus.all_documents())
        pipe.query(questions)
        bd = pipe.breakdown()
        gen_frac = bd["generation"] / max(sum(
            bd.get(s, 0.0) for s in
            ("query_embed", "retrieval", "rerank", "generation")), 1e-9)
        s = llm.stats.summary()
        print(f"{arch:22s} ttft={s['ttft_mean_s'] * 1e3:7.1f}ms "
              f"tpot={s['tpot_mean_s'] * 1e3:6.1f}ms "
              f"generation={100 * gen_frac:4.1f}% of query latency")


if __name__ == "__main__":
    main()
