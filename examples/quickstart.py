"""RAGPerf quickstart: build a pipeline, index a corpus, benchmark a mixed
query/update workload, print performance + quality metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.pipeline import PipelineConfig, RAGPipeline
from repro.monitor.monitor import MonitorConfig, ResourceMonitor
from repro.workload.corpus import CorpusConfig, SyntheticCorpus
from repro.workload.generator import WorkloadConfig
from repro.workload.runner import run_workload


def main():
    # 1. a knowledge corpus (synthetic wiki-style with known facts)
    corpus = SyntheticCorpus(CorpusConfig(n_docs=64, modality="text"))

    # 2. a configurable pipeline: every knob from the paper's §3.3
    pipe = RAGPipeline(PipelineConfig(
        embedder="hash", embed_dim=384,
        chunk_method="separator", chunk_size=512,
        index_type="ivf", nlist=16, nprobe=8, quant="none",
        use_hybrid=True, flat_capacity=512,
        reranker="overlap", retrieve_k=8, rerank_k=3,
        llm="extractive",
    ))

    # 3. decoupled low-overhead monitor (paper §3.4)
    monitor = ResourceMonitor(MonitorConfig(interval_s=0.05)).start()
    monitor.add_gauge("db_live", lambda: pipe.db.stats()["live"])

    n = pipe.index_documents(corpus.all_documents())
    print(f"indexed {n} chunks from {corpus.cfg.n_docs} documents")

    # 4. a workload: 80% queries / 15% updates / 5% inserts, zipfian hotspot
    res = run_workload(pipe, corpus, WorkloadConfig(
        query_frac=0.8, update_frac=0.15, insert_frac=0.05,
        distribution="zipfian", n_requests=120, seed=0), query_batch=4)

    monitor.stop()
    print(f"\nthroughput: {res.qps:.1f} requests/s")
    print("stage breakdown (s):",
          {k: round(v, 3) for k, v in pipe.breakdown().items()})
    print("quality:", {k: round(v, 3) for k, v in res.quality.items()})
    print("db stats:", {k: round(v, 1) for k, v in pipe.db_stats().items()
                        if not k.endswith("_s")})
    print("monitor summary:", {k: round(v.get("mean", 0), 2)
                               for k, v in monitor.summary().items()})


if __name__ == "__main__":
    main()
