"""RAGPerf quickstart: declare a pipeline as a PipelineSpec, build it via
the component registry, index a corpus, benchmark a mixed query/update
workload, print performance + quality metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.registry import build
from repro.core.spec import PipelineSpec, StageSpec
from repro.monitor.monitor import MonitorConfig, ResourceMonitor
from repro.workload.corpus import CorpusConfig, SyntheticCorpus
from repro.workload.generator import WorkloadConfig
from repro.workload.runner import run_workload


def main():
    # 1. a knowledge corpus (synthetic wiki-style with known facts)
    corpus = SyntheticCorpus(CorpusConfig(n_docs=64, modality="text"))

    # 2. a declarative pipeline spec: each stage names a registered
    #    component + its options (every knob from the paper's §3.3).
    #    The same spec serializes to JSON — see examples/specs/.
    spec = PipelineSpec(
        embedder=StageSpec("hash", {"dim": 384}),
        chunker=StageSpec("separator", {"size": 512}),
        vectordb=StageSpec("jax", {"index_type": "ivf", "nlist": 16,
                                   "nprobe": 8, "quant": "none",
                                   "use_hybrid": True,
                                   "flat_capacity": 512}),
        reranker=StageSpec("overlap"),
        llm=StageSpec("extractive"),
        retrieve_k=8, rerank_k=3,
    )
    pipe = build(spec)
    print("spec:", spec.to_json(indent=None))

    # 3. decoupled low-overhead monitor (paper §3.4)
    monitor = ResourceMonitor(MonitorConfig(interval_s=0.05)).start()
    monitor.add_gauge("db_live", lambda: pipe.db.stats()["live"])

    n = pipe.index_documents(corpus.all_documents())
    print(f"indexed {n} chunks from {corpus.cfg.n_docs} documents")

    # 4. a workload: 80% queries / 15% updates / 5% inserts, zipfian hotspot
    res = run_workload(pipe, corpus, WorkloadConfig(
        query_frac=0.8, update_frac=0.15, insert_frac=0.05,
        distribution="zipfian", n_requests=120, seed=0), query_batch=4)

    monitor.stop()
    print(f"\nthroughput: {res.qps:.1f} requests/s")
    print("stage breakdown (s):",
          {k: round(v, 3) for k, v in pipe.breakdown().items()})
    print("per-request stage latency (ms):",
          {k: round(v * 1e3, 2)
           for k, v in pipe.traces[-1].latency_s.items()})
    print("quality:", {k: round(v, 3) for k, v in res.quality.items()})
    print("db stats:", {k: round(v, 1) for k, v in pipe.db_stats().items()
                        if not k.endswith("_s")})
    print("monitor summary:", {k: round(v.get("mean", 0), 2)
                               for k, v in monitor.summary().items()})


if __name__ == "__main__":
    main()
