"""End-to-end driver: train a small LM on the RAG corpus for a few hundred
steps (deterministic data pipeline, AdamW, periodic async checkpoints,
restart-from-latest), then plug the trained model into the serving path.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200]

The same code path drives the full configs on a production mesh
(``python -m repro.launch.train --arch llama3_8b --production-mesh``).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.data import CorpusDataSource, DataConfig
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step, train_state_shape)
from repro.workload.corpus import CorpusConfig, SyntheticCorpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/ragperf_train_e2e")
    args = ap.parse_args()

    cfg = ModelConfig(name="rag-lm-20m", family="dense", n_layers=4,
                      d_model=256, n_heads=4, n_kv_heads=2, d_ff=1024,
                      vocab_size=8192, activation="swiglu", rope_type="rope",
                      remat="none", dtype="float32")
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    corpus = SyntheticCorpus(CorpusConfig(n_docs=256))
    dcfg = DataConfig(source="corpus", seq_len=128, global_batch=8)
    data = CorpusDataSource(corpus, dcfg, cfg.vocab_size)

    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=20,
                                       total_steps=args.steps))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    restored, start = ckpt.restore_latest(train_state_shape(cfg, tcfg))
    if restored is not None:
        state = jax.tree.map(jnp.asarray, restored)
        print(f"restarting from checkpoint at step {start}")
    else:
        state, start = init_train_state(jax.random.PRNGKey(0), cfg, tcfg), 0

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    t0 = time.perf_counter()
    for s in range(start, args.steps):
        state, metrics = step_fn(state, data.batch(s))
        if s % 20 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e}")
        if (s + 1) % 100 == 0:
            ckpt.save(state, s + 1)              # async write
    ckpt.save(state, args.steps, blocking=True)
    wall = time.perf_counter() - t0
    tok = (args.steps - start) * dcfg.global_batch * dcfg.seq_len
    print(f"{tok / wall:.0f} tok/s over {args.steps - start} steps")
    print(f"checkpoints: {ckpt.list_checkpoints()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
