"""Paper §5.5 scenario: continuous updates vs index freshness.

Runs the same 50/50 query/update workload against three configurations and
prints the latency/accuracy trade-off the paper's Fig. 9 shows:
  1. no temp flat index  -> stable latency, stale answers;
  2. hybrid + uniform    -> fresh answers, latency sawtooth (rebuilds);
  3. hybrid + zipfian    -> fresh answers, gentler growth (fewer uniques).

    PYTHONPATH=src python examples/update_freshness.py
"""
import numpy as np

from repro.core.pipeline import PipelineConfig, RAGPipeline
from repro.workload.corpus import CorpusConfig, SyntheticCorpus
from repro.workload.generator import WorkloadConfig
from repro.workload.runner import run_workload


def run_config(name, use_hybrid, distribution):
    corpus = SyntheticCorpus(CorpusConfig(n_docs=64, seed=1))
    pipe = RAGPipeline(PipelineConfig(
        index_type="ivf", nlist=16, nprobe=8, capacity=1 << 15,
        use_hybrid=use_hybrid, flat_capacity=96, rebuild_threshold=0.9))
    pipe.index_documents(corpus.all_documents())
    res = run_workload(pipe, corpus, WorkloadConfig(
        query_frac=0.5, update_frac=0.5, n_requests=120,
        distribution=distribution, seed=2), query_batch=4)
    lat = res.latencies.get("query", [0.0])
    print(f"{name:18s} qps={res.qps:6.1f} "
          f"query p50={np.median(lat) * 1e3:6.1f}ms "
          f"p95={np.percentile(lat, 95) * 1e3:6.1f}ms "
          f"rebuilds={pipe.db.stats()['rebuilds']:.0f} "
          f"recall={res.quality['context_recall']:.2f} "
          f"exact={res.quality['exact']:.2f}")


def main():
    print("config             throughput  latency                rebuilds  quality")
    run_config("no-flat uniform", False, "uniform")
    run_config("hybrid uniform", True, "uniform")
    run_config("hybrid zipfian", True, "zipfian")


if __name__ == "__main__":
    main()
